//! Paper-figure bench harness: regenerates every table and figure of the
//! evaluation section (`cargo bench`, or `cargo bench -- fig9` to filter).
//!
//! | id     | paper content                                              |
//! |--------|------------------------------------------------------------|
//! | table1 | system specification                                       |
//! | fig1   | CUTLASS utilization A100 vs GH200 (GPU baseline model)     |
//! | fig7a  | roofline: baseline/SUMMA x base/optimal layout             |
//! | fig7b  | dataflow-pattern comparison (2D tiling)                    |
//! | fig7c  | 2D SUMMA vs 3D split-K SUMMA                               |
//! | fig7d  | flat GEMM: 2D vs 3D + cluster remap                        |
//! | fig8   | pipeline stages: compute- vs store-intensive               |
//! | fig9   | compute-bound GEMM vs GH200 CUTLASS/DeepGEMM               |
//! | fig10  | flat GEMM TFLOPS vs GH200                                  |
//! | fig11  | flat GEMM HBM bandwidth utilization                        |
//! | fig12  | portability: SoftHier-A100/GH200 vs the matching GPUs      |
//! | workload | transformer serving-suite batched autotuning (engine)    |
//! | dse    | hardware design-space sweep (TFLOPS-vs-cost Pareto front,  |
//! |        | square ladder + rectangular-mesh case)                     |
//! | energy | energy-aware 3-axis DSE (perf/cost/energy frontier)        |
//! | tiered | analytic-first tiered tuning calibration vs exhaustive     |
//! | serve  | schedule-serving replay of the committed Zipf trace        |
//! |        | (exact/neighbor hit rates, time-to-schedule percentiles)   |
//! | check  | static deployment checker over every preset × built-in     |
//! |        | suite (lint throughput; gates the zero-simulation contract)|
//! | graph  | multi-op workload-graph fusion: attention-prefill SPM      |
//! |        | residency + fused-vs-unfused HBM traffic contract          |
//!
//! Absolute numbers come from the analytical-contention SoftHier model and
//! the calibrated GPU baselines (see DESIGN.md §Substitutions); the point
//! of comparison with the paper is the *shape* of each result (who wins,
//! by what factor, where crossovers sit). Results are archived in
//! EXPERIMENTS.md.
//!
//! `--json PATH` additionally writes every headline metric (TFLOP/s,
//! utilization, speedup ratios) plus per-figure wall-clock to a
//! machine-readable artifact (`BENCH_results.json`); the CI perf gate
//! (`cargo run --bin bench_gate`) compares it against the committed
//! `bench_baseline.json`.
//!
//! `--cache PATH` attaches the persistent simulation cache to the `dse`
//! bench and records `dse.disk_hits` / `dse.sim_calls_with_cache` in the
//! JSON artifact; CI runs the bench twice against one path and fails if
//! the second run reports zero disk hits (persistence exercised
//! end-to-end on every push). These metrics only exist under `--cache`,
//! so the gated (cache-less) artifact stays exactly the pinned set.

use std::sync::OnceLock;
use std::time::Instant;

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::engine::{Engine, TunePolicy};
use dit::coordinator::{autotune, simulate_schedule};
use dit::dse::{DseOptions, Objective, SweepSpec};
use dit::perfmodel::{ridge_intensity, roofline_tflops, workloads, GpuSpec};
use dit::report::{AsciiPlot, Table};
use dit::schedule::{retune_tk, Dataflow, Schedule};
use dit::sim::{sim_counters, RunStats};
use dit::util::json::Json;

/// Collects the machine-readable side of the bench run: gateable metrics
/// (deterministic model outputs) and per-figure wall-clock (recorded
/// separately — wall time is machine noise, the gate ignores it).
struct Recorder {
    metrics: Vec<(String, String, f64, bool)>,
    wall_ms: Vec<(String, f64)>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { metrics: Vec::new(), wall_ms: Vec::new() }
    }

    fn rec(&mut self, figure: &str, metric: &str, value: f64, higher_is_better: bool) {
        self.metrics.push((figure.to_string(), metric.to_string(), value, higher_is_better));
    }

    fn wall(&mut self, figure: &str, ms: f64) {
        self.wall_ms.push((figure.to_string(), ms));
    }

    fn to_json(&self) -> Json {
        let mut metrics = Json::arr();
        for (figure, metric, value, higher) in &self.metrics {
            metrics = metrics.push(
                Json::obj()
                    .field("figure", figure.as_str())
                    .field("metric", metric.as_str())
                    .field("value", *value)
                    .field("higher_is_better", *higher),
            );
        }
        let mut wall = Json::arr();
        for (figure, ms) in &self.wall_ms {
            wall = wall.push(Json::obj().field("figure", figure.as_str()).field("ms", *ms));
        }
        Json::obj()
            .field("schema", 1i64)
            .field("generated_by", "dit bench harness")
            .field("metrics", metrics)
            .field("wall_clock_ms", wall)
    }

    fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }
}

/// Persistent-cache path for the `dse` bench (`--cache PATH`).
static DSE_CACHE: OnceLock<String> = OnceLock::new();

fn main() {
    let mut json_path: Option<String> = None;
    let mut filters: Vec<String> = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        if a == "--json" {
            match it.next() {
                Some(p) => json_path = Some(p),
                None => {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                }
            }
        } else if a == "--cache" {
            match it.next() {
                Some(p) => {
                    let _ = DSE_CACHE.set(p);
                }
                None => {
                    eprintln!("--cache needs a path");
                    std::process::exit(2);
                }
            }
        } else if a.starts_with('-') {
            // `cargo bench` forwards harness flags (e.g. --bench); ignore.
        } else {
            filters.push(a);
        }
    }
    // A filter matches its exact id, or a family prefix (`fig7` selects
    // fig7a..fig7d) — but never a longer numeric id (`fig1` must not pull
    // in fig10/fig11/fig12, or the CI fast subset silently grows).
    let matches = |a: &str, id: &str| match id.strip_prefix(a) {
        Some(rest) => !rest.starts_with(|c: char| c.is_ascii_digit()),
        None => false,
    };
    let figs: [(&str, fn(&mut Recorder)); 18] = [
        ("table1", table1),
        ("fig1", fig1),
        ("fig7a", fig7a),
        ("fig7b", fig7b),
        ("fig7c", fig7c),
        ("fig7d", fig7d),
        ("fig8", fig8),
        ("fig9", fig9),
        ("fig10", fig10),
        ("fig11", fig11),
        ("fig12", fig12),
        ("workload", workload_bench),
        ("dse", dse_bench),
        ("energy", energy_bench),
        ("tiered", tiered_bench),
        ("serve", serve_bench),
        ("check", check_bench),
        ("graph", graph_bench),
    ];
    // A filter that selects nothing is a typo (or a stale CI list): fail
    // loudly rather than emit an empty artifact with exit code 0.
    for a in &filters {
        if !figs.iter().any(|(id, _)| matches(a, id)) {
            eprintln!("error: filter {a:?} matches no bench id");
            std::process::exit(2);
        }
    }
    let t0 = Instant::now();
    let mut rec = Recorder::new();
    for (id, f) in figs {
        if filters.is_empty() || filters.iter().any(|a| matches(a, id)) {
            let t = Instant::now();
            f(&mut rec);
            rec.wall(id, t.elapsed().as_secs_f64() * 1e3);
        }
    }
    if let Some(path) = &json_path {
        match rec.save(path) {
            Ok(()) => eprintln!("[wrote {path}: {} metrics]", rec.metrics.len()),
            Err(e) => {
                eprintln!("[failed to write {path}: {e}]");
                std::process::exit(1);
            }
        }
    }
    eprintln!("\n[bench harness completed in {:.1?}]", t0.elapsed());
}

fn sim(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> RunStats {
    simulate_schedule(arch, shape, sched)
        .unwrap_or_else(|e| panic!("{} on {shape}: {e}", sched.name()))
}

/// Best-of-candidates for a shape — "we iterate through our predefined
/// schedule candidates ... to automatically select the kernel achieving the
/// best performance" (§4.1.4).
fn best(arch: &ArchConfig, shape: GemmShape) -> (Schedule, RunStats) {
    let r = autotune(arch, shape).expect("autotune");
    (r.best().schedule.clone(), r.best().stats.clone())
}

// --------------------------------------------------------------------
fn table1(r: &mut Recorder) {
    let a = ArchConfig::gh200_like();
    let mut t = Table::new(
        "Table 1: System Specifications (GH200-matched SoftHier instance)",
        &["item", "value", "paper"],
    );
    t.row(vec![
        "system".into(),
        format!("{}x{} tiles, {}-bit NoC links", a.rows, a.cols, a.noc.link_bits),
        "32x32 tiles, 4096-bit NoC link width".into(),
    ]);
    t.row(vec![
        "hbm".into(),
        format!(
            "{}x2 channels (west+south), {:.0} GB/s total",
            a.hbm.channels_per_edge,
            a.hbm.total_gbps()
        ),
        "32x2 channels, 4 TB/s".into(),
    ]);
    t.row(vec![
        "tile".into(),
        format!(
            "{}x{} CE array @ {:.3} GHz = {:.2} TFLOPS FP8, {} KB L1 @ {:.0} GB/s",
            a.tile.ce_m,
            a.tile.ce_n,
            a.tile.clock_ghz,
            a.tile.peak_tflops(),
            a.tile.l1_bytes / 1024,
            a.tile.l1_gbps
        ),
        "64x16 CE, 1.93 TFLOPS FP8, 384 KB".into(),
    ]);
    t.row(vec![
        "summary".into(),
        format!("{:.0} TFLOPS peak, {:.0} GB/s HBM", a.peak_tflops(), a.hbm.total_gbps()),
        "1979 TFLOPS, 4 TB/s".into(),
    ]);
    print!("\n{}", t.markdown());
    r.rec("table1", "peak_tflops", a.peak_tflops(), true);
    r.rec("table1", "hbm_gbps", a.hbm.total_gbps(), true);
}

// --------------------------------------------------------------------
fn fig1(r: &mut Recorder) {
    let a100 = GpuSpec::a100();
    let gh200 = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 1: CUTLASS utilization, A100 vs GH200 (analytical GPU baseline)",
        &["shape", "A100 util %", "GH200 util %"],
    );
    let (mut sum_a, mut sum_g, mut n) = (0.0f64, 0.0f64, 0usize);
    for shape in workloads::compute_bound() {
        let ua = 100.0 * a100.utilization(a100.cutlass_tflops(shape));
        let ug = 100.0 * gh200.utilization(gh200.cutlass_tflops(shape));
        sum_a += ua;
        sum_g += ug;
        n += 1;
        t.row(vec![shape.to_string(), format!("{ua:.1}"), format!("{ug:.1}")]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: the newer/larger GH200 shows LOWER average utilization than A100)");
    r.rec("fig1", "a100_mean_util_pct", sum_a / n as f64, true);
    r.rec("fig1", "gh200_mean_util_pct", sum_g / n as f64, true);
}

// --------------------------------------------------------------------
fn fig7a(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let shape = workloads::compute_intensive();
    let mk = |dataflow: Dataflow, opt: bool| {
        let base = match dataflow {
            Dataflow::Baseline => Schedule::baseline(&arch, shape),
            _ => Schedule::summa(&arch, shape),
        };
        retune_tk(&arch, shape, &Schedule { opt_layout: opt, ..base })
    };
    let series = [
        ("baseline w/o optimal layout", mk(Dataflow::Baseline, false)),
        ("baseline w/ optimal layout", mk(Dataflow::Baseline, true)),
        ("SUMMA w/o optimal layout", mk(Dataflow::Summa, false)),
        ("SUMMA w/ optimal layout", mk(Dataflow::Summa, true)),
    ];
    let mut t = Table::new(
        format!("Fig 7a: roofline, {shape} (ridge {:.0} FLOP/B)", ridge_intensity(&arch)),
        &["schedule", "intensity FLOP/B", "TFLOP/s", "roofline ceiling", "util %"],
    );
    let mut plot = AsciiPlot::new("Fig 7a roofline", "operational intensity (FLOP/B)", "TFLOP/s");
    let mut pts = Vec::new();
    for (name, sched) in &series {
        let stats = sim(&arch, shape, sched);
        t.row(vec![
            name.to_string(),
            format!("{:.1}", stats.intensity()),
            format!("{:.1}", stats.tflops()),
            format!("{:.1}", roofline_tflops(&arch, stats.intensity())),
            format!("{:.1}", 100.0 * stats.utilization()),
        ]);
        pts.push((stats.intensity(), stats.tflops()));
        if *name == "SUMMA w/ optimal layout" {
            r.rec("fig7a", "summa_opt_tflops", stats.tflops(), true);
            r.rec("fig7a", "summa_opt_util_pct", 100.0 * stats.utilization(), true);
        }
    }
    // Roofline ceiling curve.
    let ceiling: Vec<(f64, f64)> = (0..40)
        .map(|i| {
            let x = 1.5f64.powi(i);
            (x, roofline_tflops(&arch, x))
        })
        .collect();
    plot.series('*', pts);
    plot.series('.', ceiling);
    print!("\n{}", t.markdown());
    print!("{}", plot.render());
    println!("(paper: layout lifts baseline toward the memory ceiling; SUMMA lifts intensity;\n SUMMA + optimal layout approaches the compute ceiling)");
}

// --------------------------------------------------------------------
fn fig7b(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let shapes = [
        GemmShape::new(4096, 2112, 7168),
        GemmShape::new(4096, 4096, 7168),
        GemmShape::new(4096, 7168, 2048),
        GemmShape::new(8192, 8192, 4096),
    ];
    let mut t = Table::new(
        "Fig 7b: dataflow patterns, 2D tiling (TFLOP/s)",
        &["shape", "baseline", "SUMMA", "systolic", "sys/SUMMA g4", "SUMMA/sys g2"],
    );
    let mut summa_sum = 0.0f64;
    for shape in shapes {
        let b = retune_tk(&arch, shape, &Schedule {
            opt_layout: true,
            ..Schedule::baseline(&arch, shape)
        });
        let s = Schedule::summa(&arch, shape);
        let sy = Schedule::systolic(&arch, shape);
        let h1 = retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SystolicOverSumma { group: 4 },
            ..Schedule::summa(&arch, shape)
        });
        let h2 = retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SummaOverSystolic { group: 2 },
            ..Schedule::summa(&arch, shape)
        });
        let summa_tflops = sim(&arch, shape, &s).tflops();
        summa_sum += summa_tflops;
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", sim(&arch, shape, &b).tflops()),
            format!("{summa_tflops:.0}"),
            format!("{:.0}", sim(&arch, shape, &sy).tflops()),
            format!("{:.0}", sim(&arch, shape, &h1).tflops()),
            format!("{:.0}", sim(&arch, shape, &h2).tflops()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: whether tiles start simultaneously drives the differences;\n SUMMA leads on compute-intensive shapes)");
    r.rec("fig7b", "mean_summa_tflops", summa_sum / shapes.len() as f64, true);
}

// --------------------------------------------------------------------
fn fig7c(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let shape = GemmShape::new(4096, 2112, 7168);
    let mut t = Table::new(
        "Fig 7c: 2D SUMMA vs 3D (split-K) SUMMA",
        &["schedule", "TN", "TFLOP/s", "util %"],
    );
    let s2d = Schedule::summa(&arch, shape);
    let st = sim(&arch, shape, &s2d);
    t.row(vec![
        "2D SUMMA".into(),
        format!("{}", s2d.plan(&arch, shape).tn),
        format!("{:.0}", st.tflops()),
        format!("{:.1}", 100.0 * st.utilization()),
    ]);
    let mut best_splitk = 0.0f64;
    for splits in [2, 4, 8] {
        let s = Schedule::splitk(&arch, shape, splits);
        let stats = sim(&arch, shape, &s);
        best_splitk = best_splitk.max(stats.tflops());
        t.row(vec![
            format!("3D SUMMA split-K={splits}"),
            format!("{}", s.plan(&arch, shape).tn),
            format!("{:.0}", stats.tflops()),
            format!("{:.1}", 100.0 * stats.utilization()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper Insight 3: 3D tiling turns the ragged TN=66 slices into\n matrix-engine-friendly TN=528 tiles and lifts utilization)");
    r.rec("fig7c", "best_splitk_tflops", best_splitk, true);
}

// --------------------------------------------------------------------
fn fig7d(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let shape = GemmShape::new(64, 2112, 7168);
    let mut t = Table::new(
        "Fig 7d: flat GEMM (LLM decode) — cluster dimension remap",
        &["schedule", "logical grid", "TFLOP/s", "HBM util %"],
    );
    let s2d = Schedule::summa(&arch, shape);
    let st = sim(&arch, shape, &s2d);
    t.row(vec![
        "2D SUMMA (32x32)".into(),
        "32x32".into(),
        format!("{:.0}", st.tflops()),
        format!("{:.1}", 100.0 * st.hbm_utilization()),
    ]);
    let (mut best_tflops, mut best_hbm_util) = (0.0f64, 0.0f64);
    for splits in [8, 16, 32] {
        let s = Schedule::flat_remap(&arch, shape, splits);
        let stats = sim(&arch, shape, &s);
        if stats.tflops() > best_tflops {
            best_tflops = stats.tflops();
            best_hbm_util = 100.0 * stats.hbm_utilization();
        }
        t.row(vec![
            format!("3D split-K={splits} + remap"),
            format!("1x{} x{splits}", s.logical.1),
            format!("{:.0}", stats.tflops()),
            format!("{:.1}", 100.0 * stats.hbm_utilization()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper Insight 4: remapping 32x32 -> 1x1024 logical with 3D tiling\n gives hardware-favorable tiles and much higher bandwidth use)");
    r.rec("fig7d", "best_remap_tflops", best_tflops, true);
    r.rec("fig7d", "best_remap_hbm_util_pct", best_hbm_util, true);
}

// --------------------------------------------------------------------
fn fig8(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let cases = [
        ("compute-intensive (Fig 8a)", workloads::compute_intensive()),
        ("store-intensive (Fig 8b)", workloads::store_intensive()),
    ];
    let mut t = Table::new(
        "Fig 8: pipeline stages (makespan, microseconds; lower is better)",
        &["case", "1 stage", "2 stages", "4 stages", "8 stages"],
    );
    for (name, shape) in cases {
        let mut row = vec![format!("{name} {shape}")];
        let mut best_us = f64::INFINITY;
        for stages in [1usize, 2, 4, 8] {
            let s = Schedule { pipeline_stages: stages, ..Schedule::summa(&arch, shape) };
            let stats = sim(&arch, shape, &s);
            best_us = best_us.min(stats.makespan_ns / 1e3);
            row.push(format!("{:.1}", stats.makespan_ns / 1e3));
        }
        t.row(row);
        let metric =
            if name.starts_with("compute") { "compute_best_us" } else { "store_best_us" };
        r.rec("fig8", metric, best_us, false);
    }
    print!("\n{}", t.markdown());
    println!("(paper: pipelining only wastes time on compute-intensive shapes, but\n reduces HBM store contention on store-intensive ones — up to a point)");
}

// --------------------------------------------------------------------
fn fig9(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 9: compute-bound GEMM vs GH200 (TFLOP/s)",
        &["shape", "DiT (best)", "schedule", "CUTLASS", "DeepGEMM", "speedup"],
    );
    let (mut sum_tflops, mut sum_speedup, mut min_speedup, mut n) =
        (0.0f64, 0.0f64, f64::INFINITY, 0usize);
    for shape in workloads::compute_bound() {
        let (sched, stats) = best(&arch, shape);
        let cut = gpu.cutlass_tflops(shape);
        let deep = gpu.deepgemm_tflops(shape);
        let best_gpu = cut.max(deep);
        let speedup = stats.tflops() / best_gpu;
        sum_tflops += stats.tflops();
        sum_speedup += speedup;
        min_speedup = min_speedup.min(speedup);
        n += 1;
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.tflops()),
            sched.name(),
            format!("{:.0}", cut),
            format!("{:.0}", deep),
            format!("{speedup:.2}x"),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: 1.2-1.5x higher TFLOPS than either library for all matrices)");
    r.rec("fig9", "mean_dit_tflops", sum_tflops / n as f64, true);
    r.rec("fig9", "mean_speedup", sum_speedup / n as f64, true);
    r.rec("fig9", "min_speedup", min_speedup, true);
}

// --------------------------------------------------------------------
fn fig10(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 10: flat GEMM performance vs GH200 (TFLOP/s)",
        &["shape", "DiT (best)", "schedule", "CUTLASS", "DeepGEMM", "speedup"],
    );
    let (mut sum_tflops, mut sum_speedup, mut min_speedup, mut n) =
        (0.0f64, 0.0f64, f64::INFINITY, 0usize);
    for shape in workloads::flat() {
        let (sched, stats) = best(&arch, shape);
        let cut = gpu.cutlass_tflops(shape);
        let deep = gpu.deepgemm_tflops(shape);
        let best_gpu = cut.max(deep);
        let speedup = stats.tflops() / best_gpu;
        sum_tflops += stats.tflops();
        sum_speedup += speedup;
        min_speedup = min_speedup.min(speedup);
        n += 1;
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.tflops()),
            sched.name(),
            format!("{:.0}", cut),
            format!("{:.0}", deep),
            format!("{speedup:.2}x"),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: ~1.2-2.0x speedup in the memory-bound decode regime)");
    r.rec("fig10", "mean_dit_tflops", sum_tflops / n as f64, true);
    r.rec("fig10", "mean_speedup", sum_speedup / n as f64, true);
    r.rec("fig10", "min_speedup", min_speedup, true);
}

// --------------------------------------------------------------------
fn fig11(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 11: flat GEMM HBM bandwidth utilization",
        &["shape", "DiT GB/s", "DiT util %", "GPU GB/s", "GPU util %"],
    );
    let (mut sum_util, mut n) = (0.0f64, 0usize);
    for shape in workloads::flat() {
        let (_, stats) = best(&arch, shape);
        let gpu_tflops = gpu.cutlass_tflops(shape).max(gpu.deepgemm_tflops(shape));
        let gpu_bw = gpu.achieved_gbps(shape, gpu_tflops);
        sum_util += 100.0 * stats.hbm_utilization();
        n += 1;
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.hbm_gbps()),
            format!("{:.1}", 100.0 * stats.hbm_utilization()),
            format!("{:.0}", gpu_bw),
            format!("{:.1}", 100.0 * gpu_bw / gpu.hbm_gbps),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: DiT achieves higher HBM bandwidth utilization in this regime)");
    r.rec("fig11", "mean_dit_hbm_util_pct", sum_util / n as f64, true);
}

// --------------------------------------------------------------------
fn workload_bench(r: &mut Recorder) {
    let arch = ArchConfig::gh200_like();
    let engine = Engine::new(&arch);
    let suite = Workload::builtin("transformer").expect("builtin suite");
    let (calls0, nanos0) = sim_counters();
    let rep = engine.tune_workload(&suite).expect("tune_workload");
    record_sims_per_sec(r, "workload", calls0, nanos0);
    print!("\n{}", dit::report::workload_summary(&rep).markdown());
    println!(
        "aggregate: {:.0} TFLOP/s weighted over {} GEMM executions ({} per pass)",
        rep.aggregate_tflops(),
        rep.total_count(),
        dit::util::human_time_ns(rep.total_time_ns()),
    );
    println!(
        "engine: {} simulations, {} cache hits, {} workers, {:.0} ms wall",
        rep.sim_calls, rep.cache_hits, rep.workers, rep.elapsed_ms
    );
    println!("(repeated decode-step GEMMs are memoized — a serving mix tunes mostly from cache)");
    r.rec("workload", "aggregate_tflops", rep.aggregate_tflops(), true);
    r.rec("workload", "pass_time_us", rep.total_time_ns() / 1e3, false);
}

/// Multi-op workload-graph fusion: tune the builtin attention-prefill
/// graph on the flagship preset and gate the SPM-residency contract —
/// both chain intermediates must stay on-fabric and the fused pass must
/// skip a material fraction of the edge-free lowering's HBM traffic.
/// Tiered tuning keeps the simulation budget small; the pinned metrics
/// are residency/traffic contracts, not throughput, so the policy choice
/// is not itself gated.
fn graph_bench(r: &mut Recorder) {
    use dit::graph::WorkloadGraph;
    let arch = ArchConfig::gh200_like();
    let g = WorkloadGraph::builtin("attn-prefill").expect("builtin graph");
    let engine = Engine::new(&arch).with_policy(TunePolicy::Tiered { top_k: 2, explore: 1 });
    let rep = engine.tune_graph(&g).expect("tune_graph");
    print!("\n{}", dit::report::graph_edges(&rep).markdown());
    println!("{}", dit::report::graph_counters(&rep));
    r.rec("graph", "hbm_saved_pct", rep.saved_pct(), true);
    r.rec("graph", "resident_edges", rep.resident_edges() as f64, true);
    r.rec("graph", "fused_hbm_mb", rep.fused_hbm_bytes as f64 / 1e6, false);
}

/// Record the gated simulator-throughput metric for one bench id from the
/// process-wide counter delta since `(calls0, nanos0)`: simulations per
/// second of *in-simulator* time (the inverse of mean per-call latency —
/// thread times add, so this is conservative vs wall-clock rate), plus
/// the total in-simulator wall-clock as an ungated timing entry. A
/// cache-warm run may execute zero simulations; it records 0 and relies
/// on cache runs writing separate, ungated artifacts.
fn record_sims_per_sec(r: &mut Recorder, figure: &str, calls0: u64, nanos0: u64) {
    let (calls1, nanos1) = sim_counters();
    let d_calls = calls1.saturating_sub(calls0);
    let d_nanos = nanos1.saturating_sub(nanos0);
    let sims_per_sec =
        if d_nanos > 0 { d_calls as f64 / (d_nanos as f64 / 1e9) } else { 0.0 };
    println!(
        "simulator: {d_calls} simulations in {:.1} ms of sim time ({sims_per_sec:.0} sims/sec)",
        d_nanos as f64 / 1e6
    );
    r.rec(figure, "sims_per_sec", sims_per_sec, true);
    r.wall(&format!("{figure}.sim_total"), d_nanos as f64 / 1e6);
}

// --------------------------------------------------------------------
fn dse_bench(r: &mut Recorder) {
    let (calls0, nanos0) = sim_counters();
    let spec = SweepSpec::reduced();
    let w = dit::dse::suite("serving").expect("builtin DSE suite");
    let mut opts = DseOptions::default();
    if let Some(path) = DSE_CACHE.get() {
        opts.cache_path = Some(path.into());
    }
    let res = dit::dse::run_sweep(&spec, &w, &opts).expect("dse sweep");
    print!("\n{}", dit::report::dse_summary(&res).markdown());
    print!("{}", dit::report::dse_plot(&res).render());
    let frontier = res.frontier();
    println!(
        "frontier: {} non-dominated of {} evaluated ({} pruned by roofline, {} infeasible)",
        frontier.len(),
        res.points.len(),
        res.pruned.len(),
        res.infeasible.len()
    );
    println!("{}", dit::report::dse_counters(&res));
    // Is the Table 1-class 32x32 instance on/above the frontier? (1 = yes)
    let on_or_above = match res.best_at_square(32) {
        Some(p) => res.on_or_above_frontier(p) as usize as f64,
        None => 0.0,
    };
    r.rec("dse", "frontier_size", frontier.len() as f64, true);
    r.rec("dse", "evaluated", res.points.len() as f64, true);
    r.rec("dse", "best_tflops", res.best().map(|p| p.tflops).unwrap_or(0.0), true);
    r.rec("dse", "gh200_class_on_frontier", on_or_above, true);
    if DSE_CACHE.get().is_some() {
        // Persistence counters, recorded only under --cache so the gated
        // cache-less artifact keeps exactly the pinned metric set.
        r.rec("dse", "disk_hits", res.disk_hits as f64, true);
        r.rec("dse", "sim_calls_with_cache", res.sim_calls as f64, false);
    }

    // Rectangular-mesh case: the same serving suite over the wide-short
    // and tall-narrow geometries the square axis cannot express, plus
    // their square twin at twice the tile budget. Exhaustive (prune off)
    // so the evaluated count is exactly the enumeration.
    let mut rect_spec = SweepSpec::reduced();
    rect_spec.name = "rect".into();
    rect_spec.meshes = vec![(8, 16), (16, 8), (16, 16)];
    rect_spec.spm_kib = vec![384];
    let mut rect_opts = DseOptions { prune: false, ..DseOptions::default() };
    if let Some(path) = DSE_CACHE.get() {
        rect_opts.cache_path = Some(path.into());
    }
    let rect = dit::dse::run_sweep(&rect_spec, &w, &rect_opts).expect("rectangular dse sweep");
    print!("\n{}", dit::report::dse_summary(&rect).markdown());
    if let (Some(wide), Some(tall)) = (rect.best_at_mesh(8, 16), rect.best_at_mesh(16, 8)) {
        println!(
            "rect: 8x16 {:.1} TFLOP/s vs 16x8 {:.1} TFLOP/s at the same tile budget",
            wide.tflops,
            tall.tflops
        );
    }
    r.rec("dse", "rect_evaluated", rect.points.len() as f64, true);
    r.rec("dse", "rect_frontier_size", rect.frontier().len() as f64, true);
    r.rec("dse", "rect_best_tflops", rect.best().map(|p| p.tflops).unwrap_or(0.0), true);
    record_sims_per_sec(r, "dse", calls0, nanos0);
    println!("(a DSE sweep co-tunes every hardware candidate with the same engine the\n serving path uses — deployment and hardware are searched together)");
}

// --------------------------------------------------------------------
fn energy_bench(r: &mut Recorder) {
    let spec = SweepSpec::reduced();
    let w = dit::dse::suite("serving").expect("builtin DSE suite");
    let opts = DseOptions {
        objectives: vec![Objective::Perf, Objective::Cost, Objective::Energy],
        ..DseOptions::default()
    };
    let res = dit::dse::run_sweep(&spec, &w, &opts).expect("energy-aware dse sweep");
    print!("\n{}", dit::report::dse_summary(&res).markdown());
    for plot in dit::report::dse_plot_projections(&res) {
        print!("{}", plot.render());
    }
    let frontier3 = res.frontier3();
    println!(
        "3-axis frontier: {} non-dominated of {} evaluated over (cost, TFLOP/s, energy)",
        frontier3.len(),
        res.points.len()
    );
    let best_tpw = res.most_efficient().expect("non-empty sweep");
    println!(
        "efficiency winner: {} at {:.2} TFLOP/s/W ({:.2} mJ/pass, {:.1} TFLOP/s)",
        best_tpw.arch.name,
        best_tpw.tflops_per_w,
        best_tpw.energy_j * 1e3,
        best_tpw.tflops
    );
    // Balanced scalarization: half performance, the rest split over the
    // silicon and energy budgets.
    let weights = [0.5, 0.2, 0.3];
    let objectives = [Objective::Perf, Objective::Cost, Objective::Energy];
    let (winner, score) = res
        .best_scalarized(&objectives, &weights)
        .expect("valid weights")
        .expect("non-empty sweep");
    println!(
        "scalarized winner (perf=0.5, cost=0.2, energy=0.3): {} at score {score:.3}",
        winner.arch.name
    );
    let min_energy = res.points.iter().map(|p| p.energy_j).fold(f64::INFINITY, f64::min);
    r.rec("energy", "frontier3_size", frontier3.len() as f64, true);
    r.rec("energy", "best_tflops_per_w", best_tpw.tflops_per_w, true);
    r.rec("energy", "min_energy_mj", min_energy * 1e3, false);
    r.rec(
        "energy",
        "gh200_class_tflops_per_w",
        res.best_at_square(32).map(|p| p.tflops_per_w).unwrap_or(0.0),
        true,
    );
    println!("(the 3-axis sweep runs exhaustively — the roofline prune only bounds\n throughput, so it is disabled whenever energy is an objective)");
}

// --------------------------------------------------------------------
/// The calibration contract, measured: one small sweep runs twice —
/// exhaustively and under the tiered policy — and the gate pins how far
/// the tiered winners drift from the exhaustive ones (`calibration_pct`,
/// a ceiling), how much simulation the analytic ranking avoids
/// (`sims_saved_pct`, a hand-set floor: >= 80% means >= 5x fewer
/// simulator calls), and the combined simulation volume of both runs
/// (`sim_total`, a ceiling against candidate-space blowup). The prune
/// stays off so both sweeps evaluate the identical config set, and no
/// persistent cache attaches, so the artifact is fully deterministic.
fn tiered_bench(r: &mut Recorder) {
    let mut spec = SweepSpec::reduced();
    spec.name = "tiered".into();
    spec.meshes = vec![(8, 8), (8, 16), (16, 8)];
    spec.spm_kib = vec![384];
    let w = dit::dse::suite("serving").expect("builtin DSE suite");
    let exh_opts = DseOptions { prune: false, ..DseOptions::default() };
    let exh = dit::dse::run_sweep(&spec, &w, &exh_opts).expect("exhaustive sweep");
    let tier_opts = DseOptions {
        prune: false,
        policy: TunePolicy::Tiered { top_k: 1, explore: 1 },
        ..DseOptions::default()
    };
    let tier = dit::dse::run_sweep(&spec, &w, &tier_opts).expect("tiered sweep");

    assert_eq!(exh.points.len(), tier.points.len(), "sweeps must evaluate the same configs");
    let mut t = Table::new(
        "Tiered tuning: calibration against the exhaustive sweep",
        &["config", "exhaustive us/pass", "tiered us/pass", "drift %"],
    );
    // The tiered winner per shape is the best of a *subset* of the
    // exhaustive candidate set, so per-config pass time can only drift
    // up; the pinned number is the worst drift across configs.
    let mut calibration_pct = 0.0f64;
    for (e, ti) in exh.points.iter().zip(&tier.points) {
        assert_eq!(e.arch.name, ti.arch.name, "point order must match across sweeps");
        let (et, tt) = (e.report.total_time_ns(), ti.report.total_time_ns());
        let drift = 100.0 * (tt - et) / et;
        calibration_pct = calibration_pct.max(drift);
        t.row(vec![
            e.arch.name.clone(),
            format!("{:.1}", et / 1e3),
            format!("{:.1}", tt / 1e3),
            format!("{drift:+.2}"),
        ]);
    }
    print!("\n{}", t.markdown());
    let sims_saved_pct = 100.0 * (1.0 - tier.sim_calls as f64 / exh.sim_calls as f64);
    let sim_total = (exh.sim_calls + tier.sim_calls) as f64;
    println!(
        "tiered: {} simulations vs {} exhaustive ({:.1}% saved; {} candidates skipped \
         pre-cache, {} analytic rankings)",
        tier.sim_calls, exh.sim_calls, sims_saved_pct, tier.sims_saved, tier.analytic_rank_calls
    );
    println!(
        "(the analytic model earns its keep only while the tiered winner stays within a\n \
         few percent of the exhaustive one — the gate pins exactly that drift)"
    );
    r.rec("tiered", "calibration_pct", calibration_pct, false);
    r.rec("tiered", "sims_saved_pct", sims_saved_pct, true);
    r.rec("tiered", "sim_total", sim_total, false);
}

// --------------------------------------------------------------------
/// Serving-scale schedule replay of the committed Zipf request trace: a
/// cold server populates a sharded persistent cache (misses tune,
/// in-bucket neighbors borrow under the analytic ε bound); a warm
/// reopen of the same path then answers the whole working set without a
/// single simulation. Gated: the warm exact/neighbor hit rates (hard
/// floors — the trace's bucket anchors alone guarantee the exact floor
/// regardless of model drift) and the warm p99 time-to-schedule
/// (deliberately loose ceiling — wall clock is machine noise, the pin
/// only catches order-of-magnitude serving-path regressions).
fn serve_bench(r: &mut Recorder) {
    use dit::coordinator::cache::ShardedDiskCache;
    use dit::coordinator::shapedb::{load_trace, ScheduleServer, ServeConfig};
    use dit::report::{serve_counters, serve_summary};

    let arch = ArchConfig::tiny(8, 8);
    let trace = load_trace("traces/serve_zipf.txt").expect("committed serve trace");
    // ε = 0.25 is an availability-leaning serving config: borrow any
    // schedule the analytic model bounds within 25% of the shape's best.
    let cfg = ServeConfig { epsilon: 0.25, ..ServeConfig::default() };
    let dir = std::env::temp_dir().join(format!("dit-serve-bench-{}", std::process::id()));
    let _ = ShardedDiskCache::clear(&dir);

    let cold = ScheduleServer::open(&arch, &dir, cfg).expect("cold server");
    for &shape in &trace {
        cold.serve(shape).expect("cold serve");
    }
    let cold_stats = cold.stats();
    print!("\n{}", serve_summary(&cold_stats).markdown());
    println!("cold       : {}", serve_counters(&cold_stats));
    drop(cold); // flushes + compacts the sharded cache

    // Warm: the rebuild replays the cache (zero simulations), cold
    // misses answer exactly, cold borrows re-qualify as neighbors.
    let warm = ScheduleServer::open(&arch, &dir, cfg).expect("warm server");
    for &shape in &trace {
        warm.serve(shape).expect("warm serve");
    }
    let warm_stats = warm.stats();
    print!("\n{}", serve_summary(&warm_stats).markdown());
    println!("warm       : {}", serve_counters(&warm_stats));
    assert_eq!(warm_stats.sim_calls, 0, "warm replay must not simulate");
    assert_eq!(warm_stats.misses, 0, "warm replay must not miss");

    // Drain a couple of queued retunes for the printout only — the
    // gated metrics above are recorded before any retune runs.
    let exact_rate = warm_stats.exact_hits as f64 / warm_stats.requests as f64;
    let neighbor_rate = warm_stats.neighbor_hits as f64 / warm_stats.requests as f64;
    r.rec("serve", "exact_hit_rate", exact_rate, true);
    r.rec("serve", "neighbor_hit_rate", neighbor_rate, true);
    r.rec("serve", "p99_us", warm_stats.p99_us, false);
    let drained = warm.drain_retunes(2).expect("drain retunes");
    println!(
        "drained    : {drained} queued retunes; queue depth now {}",
        warm.queue_depth()
    );
    drop(warm);
    let _ = ShardedDiskCache::clear(&dir);
}

// --------------------------------------------------------------------
fn fig12(r: &mut Recorder) {
    let mut t = Table::new(
        "Fig 12: portability — utilization on spec-matched SoftHier vs real GPU",
        &["shape", "SoftHier-A100 %", "A100 CUTLASS %", "SoftHier-GH200 %", "GH200 CUTLASS %"],
    );
    let sh_a100 = ArchConfig::a100_like();
    let sh_gh200 = ArchConfig::gh200_like();
    let a100 = GpuSpec::a100();
    let gh200 = GpuSpec::gh200();
    let (mut sum_a, mut sum_g, mut n) = (0.0f64, 0.0f64, 0usize);
    for shape in workloads::compute_bound() {
        let (_, sa) = best(&sh_a100, shape);
        let (_, sg) = best(&sh_gh200, shape);
        sum_a += 100.0 * sa.utilization();
        sum_g += 100.0 * sg.utilization();
        n += 1;
        t.row(vec![
            shape.to_string(),
            format!("{:.1}", 100.0 * sa.utilization()),
            format!("{:.1}", 100.0 * a100.utilization(a100.cutlass_tflops(shape))),
            format!("{:.1}", 100.0 * sg.utilization()),
            format!("{:.1}", 100.0 * gh200.utilization(gh200.cutlass_tflops(shape))),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: CUTLASS drops on GH200; SoftHier utilization stays consistently\n high as the architecture scales — and beats its spec-matched GPU)");
    r.rec("fig12", "softhier_a100_mean_util_pct", sum_a / n as f64, true);
    r.rec("fig12", "softhier_gh200_mean_util_pct", sum_g / n as f64, true);
}

// --------------------------------------------------------------------
/// `check` bench: the static lint path (`dit check`) over every preset ×
/// built-in suite — each arch through `check_arch`, each enumerated
/// candidate through `check_schedule`. Gates three contracts: linting
/// never enters the simulator (sim_calls stays 0 — this runs single-
/// threaded so the process-wide counter delta is exact, unlike the unit
/// tests), the committed presets/suites lint with zero errors, and
/// throughput holds a configs-checked-per-second floor.
fn check_bench(r: &mut Recorder) {
    use dit::analysis::{check_arch, check_schedule};
    use dit::schedule::candidates;
    let (calls0, _) = sim_counters();
    let t = Instant::now();
    let mut subjects = 0usize;
    let mut cands = 0usize;
    let mut errors = 0usize;
    for arch in [ArchConfig::gh200_like(), ArchConfig::a100_like(), ArchConfig::tiny(8, 8)] {
        errors += check_arch(&arch).errors();
        subjects += 1;
        for suite in Workload::builtin_names() {
            let w = Workload::builtin(suite).expect("builtin suite");
            let mut seen: Vec<GemmShape> = Vec::new();
            for item in &w.items {
                if seen.contains(&item.shape) {
                    continue;
                }
                seen.push(item.shape);
                for s in candidates(&arch, item.shape) {
                    errors += check_schedule(&arch, item.shape, &s).errors();
                    subjects += 1;
                    cands += 1;
                }
            }
        }
    }
    let secs = t.elapsed().as_secs_f64().max(1e-9);
    let (calls1, _) = sim_counters();
    let sim_calls = calls1.saturating_sub(calls0);
    println!(
        "\ncheck: {subjects} subjects ({cands} schedule candidates) linted in {:.1} ms, \
         {errors} errors, {sim_calls} simulations ({:.0} configs/sec)",
        secs * 1e3,
        subjects as f64 / secs
    );
    r.rec("check", "configs_per_sec", subjects as f64 / secs, true);
    r.rec("check", "candidates_checked", cands as f64, true);
    r.rec("check", "errors", errors as f64, false);
    r.rec("check", "sim_calls", sim_calls as f64, false);
}
